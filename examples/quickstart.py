"""Quickstart: the full Being-ahead / DNNExplorer flow in one minute.

1. pull a workload from the registry (the Workload IR every subsystem
   consumes) and benchmark the two established accelerator paradigms,
2. explore the paper's hybrid paradigm with the two-level DSE,
3. do the same for a TPU pod: profile an assigned LM architecture,
   run the TPU DSE over sharding plans, print the predicted roofline,
4. close the analytic<->measured loop: microbenchmark the live kernel
   dispatch ops and evaluate a workload from the measured timings
   (the Fig. 4/5 validation methodology at kernel scale).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_arch, get_shape
from repro.core.dse.engine import benchmark_paradigm, explore_fpga
from repro.core.dse.tpu_engine import explore_tpu
from repro.core.hardware import KU115
from repro.core.workload import get_workload

print("== step 1-2: FPGA-domain benchmarking (the paper's own flow) ==")
wl = get_workload("resnet18", input_size=224)
print(f"workload: {wl.describe()}")
for p in (1, 2):
    r = benchmark_paradigm(wl, KU115, p, batch=1)
    print(f"paradigm {p}: {r.gops:7.1f} GOP/s, DSP efficiency {r.dsp_eff:.2f}")

res = explore_fpga(wl, KU115, n_particles=12, n_iters=12)
d = res.best_design
print(f"paradigm 3 (two-level DSE): {d.gops():7.1f} GOP/s "
      f"(SP={d.sp}, batch={d.batch}) — converged in "
      f"{next(i for i, v in enumerate(res.gops_trace) if v >= 0.99 * res.gops_trace[-1])}"
      f" iterations")

print("\n== step 3: the same technique on a TPU-pod (256 x v5e) ==")
cfg = get_arch("chatglm3-6b")
shape = get_shape("train_4k")
lm = get_workload("chatglm3-6b/train_4k")
print(f"workload: {lm.describe()}")
t = explore_tpu(cfg, shape, n_particles=10, n_iters=10)
a = t.best_analysis
print(f"{cfg.name} x {shape.name}: best plan SP={t.best_plan.sp} "
      f"M={t.best_plan.microbatches} "
      f"front={t.best_plan.front.dataflow} tail={t.best_plan.tail.dataflow}")
print(f"predicted per-chip terms: compute {a.compute_s:.2f}s, "
      f"memory {a.memory_s:.2f}s, collectives {a.collective_s:.2f}s "
      f"-> bottleneck: {a.dominant}")
print(f"predicted roofline fraction: {t.best_fitness:.3f}")

print("\n== step 4: measured kernels close the loop ==")
from repro.core.analytical import DesignPoint, MeasuredModel
from repro.core.workload import lm_workload
from repro.kernels.tune import TUNE_PRESETS, run_tuning

pset = TUNE_PRESETS["ci"]
calib = run_tuning(pset, cells=[("minicpm-2b", "prefill_32k")], reps=1)
wl_smoke = lm_workload(pset.arch("minicpm-2b"), pset.shape("prefill_32k"))
m = MeasuredModel(wl_smoke, calib).evaluate(DesignPoint.make())
src = m.resources
print(f"{wl_smoke.name} from measured kernel timings: "
      f"{m.latency_s * 1e3:.2f} ms/step ({m.gops:.1f} GOP/s; "
      f"{src['measured_ops']:.0f} ops measured, "
      f"{src['interpolated_ops']:.0f} roofline-interpolated)")
