"""Accelerator exploration deep-dive (the paper's §6 experiments, live).

Reproduces the scalability experiment (Fig. 10) and one DSE trace
(Fig. 11) interactively, then runs the TPU-domain DSE across three
assigned architectures — both explorers now drive the same
``AcceleratorModel`` + ``DesignSpace`` search core, so the FPGA and
TPU sections differ only in which model/space they hand it. Each
search also prints its memo-cache savings and the (throughput,
latency, efficiency) Pareto frontier.

    PYTHONPATH=src python examples/explore_accelerator.py
"""
from repro.configs import get_arch, get_shape
from repro.core.dse import benchmark_paradigm, explore_fpga, explore_tpu
from repro.core.hardware import KU115
from repro.core.workload import get_workload

print("== Fig. 10: deeper DNNs (13 -> 38 CONV layers) ==")
for extra, depth in ((0, 13), (1, 18), (3, 28), (5, 38)):
    wl = get_workload("vgg16", input_size=224, extra_per_group=extra)
    row = [f"{depth}L"]
    for p in (1, 2, 3):
        r = benchmark_paradigm(wl, KU115, p, batch=1)
        row.append(f"p{p}={r.gops:7.1f}")
    print("  " + "  ".join(row))

print("\n== Fig. 11-style DSE trace (VGG16 / KU115) ==")
res = explore_fpga(get_workload("vgg16"), KU115, n_particles=16, n_iters=12)
for i, (g, sp, b) in enumerate(zip(res.gops_trace, res.sp_trace,
                                   res.batch_trace)):
    print(f"  iter {i:2d}: best {g:7.1f} GOP/s  (SP={sp}, batch={b})")
s = res.search
print(f"  cache: {s.unique_evaluations} unique analytical evals for "
      f"{s.calls} fitness calls ({s.cache_hits} hits)")
print("  pareto frontier (throughput imgs/s, latency s, dsp-eff):")
for e in sorted(res.pareto, key=lambda e: -e.result.throughput)[:5]:
    r = e.result
    print(f"    SP={int(e.point['sp']):2d} batch={int(e.point['batch']):2d}"
          f"  thr={r.throughput:9.1f}  lat={r.latency_s * 1e3:7.2f} ms"
          f"  eff={r.efficiency:.3f}")

print("\n== TPU DSE across architecture families ==")
for arch in ("stablelm-12b", "mixtral-8x22b", "mamba2-1.3b"):
    cfg = get_arch(arch)
    shape = get_shape("train_4k")
    t = explore_tpu(cfg, shape, n_particles=10, n_iters=10)
    a = t.best_analysis
    s = t.search
    print(f"  {arch:16s}: M={t.best_plan.microbatches:2d} "
          f"front={t.best_plan.front.dataflow}/{t.best_plan.front.attn_mode} "
          f"dom={a.dominant:12s} roofline~{t.best_fitness:.3f} "
          f"(cache {s.cache_hits}/{s.calls} hits, "
          f"pareto {len(t.pareto)})")
