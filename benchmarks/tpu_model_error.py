"""§Model-accuracy (TPU domain): analytic tpu_model prediction vs the
compiled dry-run artifact, per cell — the Fig. 4/5 analogue.

The analytic model predicts *useful-work* compute time (model math at
the chosen sharding); the compiled artifact measures whatever the
lowering actually emitted. Their ratio is therefore both a model-error
check AND a waste detector: a large (compiled / predicted) ratio marks a
cell whose implementation leaves flops on the table (e.g. the einsum
MoE dispatch) — exactly what the paper's benchmarking step is for.
"""
from __future__ import annotations

from repro.configs import get_arch, get_shape
from repro.core.analytical.tpu_model import ShardPlan, TPUPlan, analyze

from benchmarks.common import emit, load_dryrun_artifacts


def run(mesh: str = "single"):
    rows = []
    for art in load_dryrun_artifacts(mesh):
        if art["status"] != "OK":
            continue
        cfg = get_arch(art["arch"])
        shape = get_shape(art["shape"])
        attn = "heads" if cfg.n_heads % 16 == 0 \
            and cfg.family != "ssm" else "seq"
        df = "IS" if shape.kind == "train" else "WS"
        sp = ShardPlan(df, attn, 16)
        plan = TPUPlan(0, sp, sp, art.get("microbatches", 1), "full",
                       16, 1)
        pred = analyze(cfg, shape, plan)
        meas = art["roofline"]["compute_s"]
        ratio = meas / max(pred.compute_s, 1e-12)
        rows.append({"arch": art["arch"], "shape": art["shape"],
                     "pred_compute_s": pred.compute_s,
                     "hlo_compute_s": meas, "hlo_over_pred": ratio})
    med = sorted(r["hlo_over_pred"] for r in rows)[len(rows) // 2] \
        if rows else 0
    emit(f"tpu_model_error_{mesh}", rows)
    print(f"[tpu-model] {len(rows)} cells; median HLO/analytic compute "
          f"ratio = {med:.2f} (>1 = backend overhead/waste; large values "
          f"flag optimization targets)")
    return {"cells": len(rows), "median_ratio": med, "pass": len(rows) > 0}


if __name__ == "__main__":
    run()
