"""§Model-accuracy (TPU domain): analytic tpu_model prediction vs the
compiled dry-run artifact, per cell — the Fig. 4/5 analogue.

The analytic model predicts *useful-work* compute time (model math at
the chosen sharding); the compiled artifact measures whatever the
lowering actually emitted. Their ratio is therefore both a model-error
check AND a waste detector: a large (compiled / predicted) ratio marks a
cell whose implementation leaves flops on the table (e.g. the einsum
MoE dispatch) — exactly what the paper's benchmarking step is for.

Runs against whichever preset's artifacts are present (``full``
preferred, else ``ci``); fails loudly with the generation command when
there are none.
"""
from __future__ import annotations

from repro.core.analytical.tpu_model import analyze
from repro.core.workload import lm_workload
from repro.launch.presets import get_preset

from benchmarks.common import emit, load_dryrun_artifacts, resolve_preset
from benchmarks.roofline_table import plan_from_artifact


def run(mesh: str = "single", preset: str = None):
    preset = resolve_preset(preset)
    pset = get_preset(preset)
    rows = []
    for art in load_dryrun_artifacts(mesh, preset):
        if art["status"] != "OK":
            continue
        cfg = pset.arch(art["arch"])
        shape = pset.shape(art["shape"])
        wl = lm_workload(cfg, shape)          # the cell's IR profile
        pred = analyze(wl, plan_from_artifact(cfg, shape, art))
        meas = art["roofline"]["compute_s"]
        ratio = meas / max(pred.compute_s, 1e-12)
        rows.append({"arch": art["arch"], "shape": art["shape"],
                     "pred_compute_s": pred.compute_s,
                     "hlo_compute_s": meas, "hlo_over_pred": ratio})
    med = sorted(r["hlo_over_pred"] for r in rows)[len(rows) // 2] \
        if rows else 0
    emit(f"tpu_model_error_{mesh}", rows)
    print(f"[tpu-model/{preset}] {len(rows)} cells; median HLO/analytic "
          f"compute ratio = {med:.2f} (>1 = backend overhead/waste; large "
          f"values flag optimization targets)")
    return {"preset": preset, "cells": len(rows), "median_ratio": med,
            "pass": len(rows) > 0}


if __name__ == "__main__":
    run()
