"""Fig. 10 reproduction: throughput of the three paradigms on deeper
VGG-like DNNs (13/18/28/38 CONV layers, 3x224x224 inputs, KU115).

Paper claims: paradigm 1 drops 77.8% from 13 to 38 layers; paradigms
2 and 3 hold peak; paradigm 3 up to 4.2x paradigm 1 at 38 layers.
"""
from __future__ import annotations

from repro.core.dse.engine import benchmark_paradigm
from repro.core.hardware import KU115
from repro.core.workload import get_workload

from benchmarks.common import emit

DEPTHS = {13: 0, 18: 1, 28: 3, 38: 5}   # extra CONV per group


def run():
    rows = []
    gops = {p: {} for p in (1, 2, 3)}
    for depth, extra in DEPTHS.items():
        wl = get_workload("vgg16", input_size=224, extra_per_group=extra)
        row = {"layers": depth}
        for p in (1, 2, 3):
            r = benchmark_paradigm(wl, KU115, p, batch=1)
            gops[p][depth] = r.gops
            row[f"p{p}_gops"] = r.gops
        rows.append(row)
    for row in rows:
        d = row["layers"]
        for p in (1, 2, 3):
            row[f"p{p}_norm"] = gops[p][d] / max(gops[p][13], 1e-9)
    emit("fig10_scalability", rows)
    p1_drop = 1.0 - gops[1][38] / gops[1][13]
    ratio = gops[3][38] / max(gops[1][38], 1e-9)
    print(f"[fig10] paradigm-1 drop 13->38L: {p1_drop*100:.1f}% "
          f"(paper 77.8%); p3/p1 @38L: {ratio:.2f}x (paper 4.2x)")
    return {"p1_drop_pct": p1_drop * 100, "p3_over_p1_38L": ratio,
            "paper_drop_pct": 77.8, "paper_ratio": 4.2,
            "pass": p1_drop >= 0.5 and ratio >= 3.0}


if __name__ == "__main__":
    run()
