"""§Model-accuracy (kernel domain): analytic roofline prediction vs the
microbenchmark measurement, per op — the Fig. 4/5 analogue at kernel
granularity.

Figs. 4/5 of the paper report the analytical models' latency error
against board measurements (1.15% / 2.17% mean). Here the measurement
is the kernel autotuner's calibration table
(``artifacts/kernels/calibration.json``) and the analytic side is the
same roofline form every analytical model in this repo uses:

    pred(op) = max(flops / F_hat, bytes / B_hat)

with (F_hat, B_hat) the *achieved-rate envelope* calibrated once from
the table itself (the best FLOP/s and byte/s any measured kernel
reached — the DNN-Chip-Predictor-style one-time calibration). The
per-op error distribution is the report: ops the roofline explains sit
near 0%, ops it cannot (launch overhead, interpreter dominance on CPU
hosts, badly-tiled kernels) stand out — the benchmarking-locates-
bottlenecks loop at kernel scale.

The second section closes the loop end-to-end: a
:class:`~repro.core.analytical.measured.MeasuredModel` evaluates each
calibrated cell's full Workload from the same table, reporting how many
ops were measured vs roofline-interpolated.

Fails loudly with the generation command when no calibration exists
(like every dry-run-artifact consumer).
"""
from __future__ import annotations

import statistics
from typing import Optional

from repro.core.analytical.interface import DesignPoint
from repro.core.analytical.measured import MeasuredModel, load_calibration
from repro.core.workload import lm_workload

from benchmarks.common import emit


def _fit_envelope(entries):
    """One-time calibration: the best achieved FLOP/s and byte/s any
    measured kernel reached (the roofline the predictions use)."""
    F = max((e["flops"] / e["best_s"] for e in entries
             if e["flops"] > 0 and e["best_s"] > 0), default=float("inf"))
    B = max((e["bytes"] / e["best_s"] for e in entries
             if e["bytes"] > 0 and e["best_s"] > 0), default=float("inf"))
    return F, B


def run(calibration_file: Optional[str] = None):
    calib = load_calibration(calibration_file)
    entries = [e for e in calib["entries"] if e["best_s"] > 0]
    F_hat, B_hat = _fit_envelope(entries)

    rows = []
    for e in entries:
        pred = max(e["flops"] / F_hat if e["flops"] else 0.0,
                   e["bytes"] / B_hat if e["bytes"] else 0.0)
        meas = e["best_s"]
        err = abs(pred - meas) / meas * 100.0
        rows.append({
            "op": e["op"], "arch": e["arch"], "shape": e["shape"],
            "winner": e["winner"], "measured_ms": meas * 1e3,
            "roofline_ms": pred * 1e3, "err_pct": err,
        })
    errs = [r["err_pct"] for r in rows]
    med_err = statistics.median(errs) if errs else float("nan")
    mean_err = statistics.fmean(errs) if errs else float("nan")
    emit("kernel_model_error", rows)

    # -- full-workload evaluation through the MeasuredModel ------------------
    # Rebuild each calibrated cell's workload at the preset's scale (the
    # tuner's smoke shrink for ci, paper scale for full) and evaluate it
    # from the same table the per-op rows came from.
    from repro.kernels.tune import TUNE_PRESETS
    pset = TUNE_PRESETS[calib["preset"]]
    wl_rows = []
    for arch, shape in calib["cells"]:
        wl = lm_workload(pset.arch(arch), pset.shape(shape))
        r = MeasuredModel(wl, calib).evaluate(DesignPoint.make())
        wl_rows.append({
            "workload": wl.name, "latency_ms": r.latency_s * 1e3,
            "gops": r.gops,
            "measured_ops": int(r.resources["measured_ops"]),
            "interpolated_ops": int(r.resources["interpolated_ops"]),
            "feasible": r.feasible,
        })
    emit("kernel_measured_workloads", wl_rows)

    ok = (len(rows) > 0 and all(r["feasible"] for r in wl_rows)
          and all(e == e and e != float("inf") for e in errs))
    print(f"[kernel-model/{calib['preset']}] {len(rows)} measured ops; "
          f"roofline-vs-measured error median {med_err:.1f}% / mean "
          f"{mean_err:.1f}% (backend={calib['backend']}, "
          f"interpret={calib['interpret']}); "
          f"{len(wl_rows)} workloads evaluated end-to-end")
    return {"preset": calib["preset"], "backend": calib["backend"],
            "ops": len(rows), "median_err_pct": med_err,
            "mean_err_pct": mean_err, "workloads": len(wl_rows),
            "pass": ok}


if __name__ == "__main__":
    run()
