"""Benchmark entry: the ci-preset static-analysis run as a tracked
smoke check.

Running the analyzer inside the benchmark roster does two things the CI
job alone can't: the pass/finding counts land in
``artifacts/bench/results.json`` next to every other tracked metric (a
creeping warning count is a perf-trajectory signal too), and the wall
time of the analysis itself is measured — the sanitizer staying
seconds-fast is what keeps it a blocking job.
"""
from __future__ import annotations


def run(preset: str = "ci") -> dict:
    from repro.analysis import run_analysis

    report = run_analysis(preset)
    counts = report.counts()
    return {
        "pass": report.ok(strict=True),
        "preset": preset,
        "passes": len(report.passes),
        "findings": len(report.findings),
        "errors": counts["error"],
        "warnings": counts["warning"],
        "info": counts["info"],
        "by_rule": report.by_rule(),
        "pass_seconds": {n: p["seconds"] for n, p in report.passes.items()},
        "findings_by_pass": {n: p["findings"]
                             for n, p in report.passes.items()},
    }
