"""Fig. 11 reproduction: two-level DSE (PSO) exploration traces for
ResNet-18/-34 and AlexNet on KU115 and ZC706 (batch unrestricted).

Paper: converges within the first ~10 of 20 iterations; best
throughputs 1642.6 / 1640.6 / 1501.2 GOP/s (KU115) and 258.9 / 236.1 /
201.6 GOP/s (ZC706).
"""
from __future__ import annotations

from repro.core.dse.engine import explore_fpga
from repro.core.hardware import KU115, ZC706
from repro.core.workload import alexnet, resnet18, resnet34

from benchmarks.common import emit

PAPER = {
    ("resnet18", "KU115"): 1642.6, ("resnet34", "KU115"): 1640.6,
    ("alexnet", "KU115"): 1501.2, ("resnet18", "ZC706"): 258.9,
    ("resnet34", "ZC706"): 236.1, ("alexnet", "ZC706"): 201.6,
}


def run(n_particles: int = 16, n_iters: int = 20):
    rows = []
    for nm, fn in (("resnet18", resnet18), ("resnet34", resnet34),
                   ("alexnet", alexnet)):
        for spec in (KU115, ZC706):
            res = explore_fpga(fn(224), spec, n_particles=n_particles,
                               n_iters=n_iters, max_batch=64)
            hist = res.gops_trace
            target = 0.99 * hist[-1]
            conv_iter = next(i for i, v in enumerate(hist) if v >= target)
            got = res.best_design.gops()
            exp = PAPER[(nm, spec.name)]
            rows.append({
                "net": nm, "board": spec.name, "gops": got,
                "paper_gops": exp, "ratio": got / exp,
                "batch": res.best_design.batch, "sp": res.best_design.sp,
                "converged_iter": conv_iter,
                "trace": [round(v, 1) for v in hist],
            })
    emit("fig11_dse_convergence", rows,
         keys=["net", "board", "gops", "paper_gops", "ratio", "batch",
               "sp", "converged_iter"])
    conv_ok = all(r["converged_iter"] <= 10 for r in rows)
    within = [r for r in rows if 0.75 <= r["ratio"] <= 1.35]
    print(f"[fig11] all converge <=10 iters: {conv_ok}; "
          f"{len(within)}/6 within 0.75-1.35x of paper GOP/s")
    return {"converged_le_10": conv_ok, "within_band": len(within),
            "pass": conv_ok and len(within) >= 5}


if __name__ == "__main__":
    run()
