"""Fig. 11 reproduction: two-level DSE exploration traces for
ResNet-18/-34 and AlexNet on KU115 and ZC706 (batch unrestricted),
through the shared ``DesignSpace`` + cached search core.

Paper: converges within the first ~10 of 20 iterations; best
throughputs 1642.6 / 1640.6 / 1501.2 GOP/s (KU115) and 258.9 / 236.1 /
201.6 GOP/s (ZC706).

On top of the paper's scalar trace this reports what the refactored
core adds: memo-cache savings (unique analytical evaluations strictly
below the n_particles*(n_iters+1) PSO budget) and the size of the
(throughput, latency, efficiency) Pareto frontier each search exposes.
"""
from __future__ import annotations

from repro.core.dse import explore_fpga
from repro.core.hardware import KU115, ZC706
from repro.core.workload import get_workload

from benchmarks.common import emit

PAPER = {
    ("resnet18", "KU115"): 1642.6, ("resnet34", "KU115"): 1640.6,
    ("alexnet", "KU115"): 1501.2, ("resnet18", "ZC706"): 258.9,
    ("resnet34", "ZC706"): 236.1, ("alexnet", "ZC706"): 201.6,
}


def run(n_particles: int = 16, n_iters: int = 20):
    rows = []
    for nm in ("resnet18", "resnet34", "alexnet"):
        wl = get_workload(nm, input_size=224)
        for spec in (KU115, ZC706):
            res = explore_fpga(wl, spec, n_particles=n_particles,
                               n_iters=n_iters, max_batch=64)
            s = res.search
            hist = res.gops_trace
            target = 0.99 * hist[-1]
            conv_iter = next(i for i, v in enumerate(hist) if v >= target)
            got = res.best_design.gops()
            exp = PAPER[(nm, spec.name)]
            rows.append({
                "net": nm, "board": spec.name, "gops": got,
                "paper_gops": exp, "ratio": got / exp,
                "batch": res.best_design.batch, "sp": res.best_design.sp,
                "converged_iter": conv_iter,
                "unique_evals": s.unique_evaluations,
                "eval_budget": n_particles * (n_iters + 1),
                "cache_hits": s.cache_hits,
                "pareto_size": len(s.pareto),
                "trace": [round(v, 1) for v in hist],
            })
    emit("fig11_dse_convergence", rows,
         keys=["net", "board", "gops", "paper_gops", "ratio", "batch",
               "sp", "converged_iter", "unique_evals", "cache_hits",
               "pareto_size"])
    conv_ok = all(r["converged_iter"] <= 10 for r in rows)
    within = [r for r in rows if 0.75 <= r["ratio"] <= 1.35]
    budget = n_particles * (n_iters + 1)
    cache_ok = all(r["unique_evals"] < budget for r in rows)
    pareto_ok = all(r["pareto_size"] >= 1 for r in rows)
    saved = sum(budget - r["unique_evals"] for r in rows)
    print(f"[fig11] all converge <=10 iters: {conv_ok}; "
          f"{len(within)}/6 within 0.75-1.35x of paper GOP/s; "
          f"cache saved {saved} analytical evals over 6 searches "
          f"(all < budget {budget}: {cache_ok}); "
          f"pareto non-empty everywhere: {pareto_ok}")
    return {"converged_le_10": conv_ok, "within_band": len(within),
            "cache_below_budget": cache_ok, "evals_saved": saved,
            "pareto_nonempty": pareto_ok,
            "pass": (conv_ok and len(within) >= 5 and cache_ok
                     and pareto_ok)}


if __name__ == "__main__":
    run()
