"""Fig. 8 reproduction: DSP efficiency of the three paradigms running
VGG16 (batch=1, 16-bit) at 12 input sizes on KU115.

Paper claims: paradigm 1 highest (dedicated stages); paradigm 3 slightly
behind for small inputs, >95% efficiency from case 3 on; paradigm 3 is
2.0x / 1.3x the generic design's efficiency at cases 1 / 2.
"""
from __future__ import annotations

from repro.core.dse.engine import benchmark_paradigm
from repro.core.hardware import KU115
from repro.core.workload import INPUT_SIZE_CASES, get_workload

from benchmarks.common import emit


def run(n_cases: int = 12):
    rows = []
    for i, sz in enumerate(INPUT_SIZE_CASES[:n_cases]):
        wl = get_workload("vgg16", input_size=sz)
        effs = {}
        for p in (1, 2, 3):
            r = benchmark_paradigm(wl, KU115, p, batch=1, seed=i)
            effs[p] = r.dsp_eff
        rows.append({"case": i + 1, "input": sz,
                     "p1_eff": effs[1], "p2_eff": effs[2],
                     "p3_eff": effs[3],
                     "p3_over_p2": effs[3] / max(effs[2], 1e-9)})
    emit("fig8_dsp_efficiency", rows)
    r1, r2 = rows[0]["p3_over_p2"], rows[1]["p3_over_p2"]
    tail_ok = all(r["p3_eff"] > 0.95 for r in rows[2:])
    print(f"[fig8] p3/p2 efficiency: case1 {r1:.2f}x (paper 2.0x), "
          f"case2 {r2:.2f}x (paper 1.3x); p3>95% after case3: {tail_ok}")
    return {"case1_ratio": r1, "case2_ratio": r2, "tail_over_95": tail_ok,
            "pass": r1 >= 1.5 and r2 >= 1.1}


if __name__ == "__main__":
    run()
