"""Fig. 9 reproduction: paradigm-3 resource distribution between the
pipeline (P) and generic (G) sections for VGG16 at 12 input sizes.

Paper: the DSE allocates more tasks/resources to the pipeline section as
the input size grows (SP and the pipeline's DSP share increase).
"""
from __future__ import annotations

from repro.core.analytical.pipeline import pipeline_dsp_used
from repro.core.analytical.generic import generic_dsp_used
from repro.core.dse.engine import benchmark_paradigm, explore_fpga
from repro.core.hardware import KU115
from repro.core.workload import INPUT_SIZE_CASES, get_workload

from benchmarks.common import emit


def run(n_cases: int = 12):
    rows = []
    for i, sz in enumerate(INPUT_SIZE_CASES[:n_cases]):
        wl = get_workload("vgg16", input_size=sz)
        res = explore_fpga(wl, KU115, batch=1, fix_batch=True,
                           n_particles=12, n_iters=12, seed=i)
        d = res.best_design
        dsp_p = pipeline_dsp_used(d.pipeline, KU115) if d.pipeline else 0.0
        dsp_g = (generic_dsp_used(d.generic, KU115)
                 if d.generic and d.generic.dataflows else 0.0)
        p1 = benchmark_paradigm(wl, KU115, 1, batch=1).gops
        p2 = benchmark_paradigm(wl, KU115, 2, batch=1).gops
        rows.append({"case": i + 1, "input": sz, "sp": d.sp,
                     "dsp_pipeline": dsp_p, "dsp_generic": dsp_g,
                     "pipe_share": dsp_p / max(dsp_p + dsp_g, 1e-9),
                     "gops": d.gops(), "p1_gops": p1, "p2_gops": p2,
                     "vs_best_pure": d.gops() / max(p1, p2, 1e-9)})
    emit("fig9_resource_split", rows)
    lo = sum(r["pipe_share"] for r in rows[:3]) / 3
    hi = sum(r["pipe_share"] for r in rows[-3:]) / 3
    # Structural claim we can verify: the two-level DSE's hybrid designs
    # match or beat both pure paradigms everywhere. The paper's secondary
    # trend (pipeline share rising with input size) does NOT reproduce
    # under our more-optimistic generic model — documented as a deviation
    # in EXPERIMENTS.md (our Alg-3 generic gets free per-layer dataflow
    # choice, so it stays efficient at large inputs where HybridDNN's
    # measured design degraded).
    good = sum(r["vs_best_pure"] >= 0.95 for r in rows)
    print(f"[fig9] pipeline DSP share small->large: {lo:.2f} -> {hi:.2f} "
          f"(paper: increasing; deviation documented); hybrid >= 0.95x "
          f"best pure paradigm in {good}/{len(rows)} cases")
    return {"small_share": lo, "large_share": hi,
            "hybrid_ge_pure": good, "cases": len(rows),
            "pass": good >= len(rows) - 1}


if __name__ == "__main__":
    run()
