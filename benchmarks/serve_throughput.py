"""§Serving throughput: a synthetic open-loop arrival trace through the
live ServeEngine, cross-checked against the analytical models — plus
the paged-KV headline comparisons.

The paper's loop is *benchmark the accelerator against the targeted
workload, then compare the analytical prediction to the measurement*
(Figs. 4/5: 1.15%/2.17% model error). Serving is the one live workload
this repo runs end-to-end, so this benchmark closes that loop for it:

* **measured** — a seeded open-loop trace (exponential inter-arrivals,
  arrivals never wait on completions) is driven through the engine on
  this host; we report tok/s, p50/p99 per-token latency (each decode
  step's wall time attributed to the tokens it emitted), request
  latency percentiles, mean slot occupancy, and KV-cache utilization
  (live context tokens / allocated cache tokens) + KV HBM bytes.
* **predicted** — the *same* serving workload expressed in the Workload
  IR (``lm_workload`` decode profile at the engine's slot batch and
  mean live context) evaluated by ``TPUModel`` (analytic, v5e) and —
  when a kernel calibration exists — ``MeasuredModel``; the row pairs
  each prediction with the measured tok/s.
* **paged vs fixed** — the same seeded mixed-context trace (short chats
  through near-window long contexts) through the fixed-slot engine and
  the :class:`~repro.serve.paged.PagedServeEngine` *at equal KV HBM
  bytes*: the paged pool holds exactly the fixed engine's
  ``n_slots * ceil(W/page_size)`` pages, yet sustains more in-flight
  requests (``max_active``) with bit-identical tokens — concurrency
  bounded by bytes, not slots.
* **prefix caching** — a repeated-system-prompt trace served cold
  (``prefix_cache=False``) and warm: the warm engine's hit rate and
  prefill-token/call savings are recorded, with token parity enforced.
* **quantized KV** — the same mixed trace through two paged engines at
  *equal KV HBM bytes*, one storing bf16 KV and one int8 KV (per-row
  scales included in the byte budget): the int8 engine must sustain
  >= 1.8x ``max_active`` at the identical byte budget, with a
  teacher-forced logit-deviation sidebar bounded by
  ``QUANT_PARITY_TOL``.

On a CPU CI host the absolute ratio is meaningless (the prediction
targets a TPU); the contract here is the *schema*: every run emits the
measured metrics plus a predicted-vs-measured throughput row into
``artifacts/bench/results.json``.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import emit


def _predictions(cfg, n_slots: int, mean_ctx: int, measured_tok_s: float):
    """Predicted serving throughput rows from the analytical models for
    the engine's decode workload (one token per slot per step)."""
    from repro.configs.base import ShapeConfig
    from repro.core.analytical.interface import DesignPoint
    from repro.core.analytical.tpu_model import TPUModel
    from repro.core.workload import lm_workload

    shape = ShapeConfig("serve_decode", seq_len=mean_ctx,
                        global_batch=n_slots, kind="decode",
                        kv_len=mean_ctx)
    wl = lm_workload(cfg, shape)
    rows = []
    point = DesignPoint.make(sp=0, log2_m=0, front_is=0, tail_is=0)
    r = TPUModel(cfg, shape, dp=1, model_axis=1, pods=1,
                 workload=wl).evaluate(point)
    if r.feasible:
        pred = n_slots / r.latency_s
        rows.append({"model": "tpu_v5e_analytic",
                     "predicted_tok_s": pred,
                     "measured_tok_s": measured_tok_s,
                     "measured_over_predicted": measured_tok_s / pred})
    try:
        from repro.core.analytical.measured import (CalibrationMissing,
                                                    MeasuredModel)
        try:
            m = MeasuredModel(wl).evaluate(DesignPoint.make())
            if m.feasible:
                pred = n_slots / m.latency_s
                rows.append({"model": "measured_calibration",
                             "predicted_tok_s": pred,
                             "measured_tok_s": measured_tok_s,
                             "measured_over_predicted":
                                 measured_tok_s / pred})
        except CalibrationMissing:
            pass                    # optional anchor; analytic row stands
    except ImportError:
        pass
    return wl, rows


def _finished_tokens(engine) -> dict:
    return {r.rid: list(r.out_tokens) for r in engine.finished}


def _paged_vs_fixed(params, cfg, rt, *, n_slots: int, window: int,
                    page_size: int, n_requests: int, max_new: int,
                    seed: int):
    """Closed-loop mixed-context trace through both engines at equal KV
    HBM bytes; returns (row, ok)."""
    from repro.models.model import page_count
    from repro.serve import PagedServeEngine, Request, ServeEngine

    rng = np.random.default_rng(seed + 1)
    lo = max(8, window // 32)
    prompts = []
    for i in range(n_requests):
        if i % 4 == 3:                      # every 4th request: long ctx
            plen = int(rng.integers(window // 4, window // 2))
        else:                               # the rest: short chats
            plen = int(rng.integers(lo, max(lo + 1, window // 8)))
        prompts.append(rng.integers(0, cfg.vocab_size, plen)
                       .astype(np.int32))

    npp = page_count(window, page_size)
    fixed = ServeEngine(params, cfg, rt, n_slots=n_slots, max_len=window)
    paged = PagedServeEngine(
        params, cfg, rt, n_slots=min(3 * n_slots, n_requests),
        max_len=window, page_size=page_size,
        page_budget=n_slots * npp + 1)      # == the fixed engine's HBM
    for eng in (fixed, paged):
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p.copy(),
                               max_new_tokens=max_new))
        eng.run(max_iters=5000)

    parity = _finished_tokens(fixed) == _finished_tokens(paged)
    fixed_bytes = fixed.kv_cache_bytes()
    paged_bytes = paged.kv_cache_bytes()
    # the pool may exceed the fixed cache only by the null page + the
    # ceil(W/ps) round-up — never by a meaningful margin
    hbm_ok = paged_bytes <= fixed_bytes * 1.05 + 1
    row = {
        "trace": "mixed_context", "window": window,
        "page_size": page_size, "requests": n_requests,
        "n_slots_fixed": n_slots, "n_slots_paged": paged.n_slots,
        "kv_hbm_bytes_fixed": fixed_bytes,
        "kv_hbm_bytes_paged": paged_bytes,
        "max_active_fixed": fixed.stats.max_active,
        "max_active_paged": paged.stats.max_active,
        "kv_utilization_fixed": fixed.stats.kv_utilization,
        "kv_utilization_paged": paged.stats.kv_utilization,
        "steps_fixed": fixed.stats.steps, "steps_paged": paged.stats.steps,
        "token_parity": parity,
    }
    ok = (parity and hbm_ok
          and paged.stats.max_active > n_slots
          and paged.stats.kv_utilization > fixed.stats.kv_utilization)
    return row, ok


def _quantized_kv_trace(cfg, *, window: int, page_size: int,
                        base_slots: int, max_new: int, seed: int):
    """Equal-HBM int8-KV vs bf16-KV closed-loop mixed trace.

    Both engines get the *same byte budget* — the bf16 pool's
    ``base_slots * ceil(W/ps)`` pages, re-denominated into int8 pages by
    the engine's own per-token byte model (int8 payload + 2 scale bytes
    per row) — and the same deterministic request sequence: three
    bucket-exact short chats then one long context, repeating. Page
    needs are exact (prompt + max_new fills its prefill bucket), so the
    expected admission pattern is computed in closed form and the
    engines' ``max_active`` is asserted against it, not eyeballed.

    head_dim is forced to 32 so the row-scale overhead is 2/64: the
    int8 byte ratio (2D)/(D+2) = 1.88 leaves headroom above the 1.8x
    concurrency bar. Accuracy rides as a sidebar: a teacher-forced
    ``logit_parity`` run over the same prompt mix must stay within
    ``QUANT_PARITY_TOL`` (greedy-token agreement is reported, not
    asserted — near-tie argmax flips are a property of the logit gap).
    """
    import dataclasses

    import jax

    from repro.models import init_params
    from repro.models.model import ModelRuntime, page_count
    from repro.serve import PagedServeEngine, Request
    from repro.serve.parity import logit_parity

    qcfg = cfg.replace(d_head=32)
    params = init_params(jax.random.PRNGKey(seed + 3), qcfg)
    rt_ref = ModelRuntime(dtype="bfloat16", remat="none", attn_chunk=32,
                          moe_dropless=True)
    rt_q = dataclasses.replace(rt_ref, kv_dtype="int8")

    npp = page_count(window, page_size)
    base = base_slots * npp                      # bf16 allocatable pages
    per_tok_base = qcfg.head_dim * 2             # bf16 bytes / token / head
    per_tok_kv = qcfg.head_dim + 2               # int8 payload + bf16 scale
    bf16_budget = base + 1                       # +1: reserved null page
    int8_budget = base * per_tok_base // per_tok_kv + 1

    # -- deterministic trace: prompt + max_new exactly fills its prefill
    # bucket, so pages_for == the scatter span == the closed-form need
    short_bucket, long_bucket = window // 8, window // 2
    short_need = page_count(short_bucket, page_size)
    long_need = page_count(long_bucket, page_size)
    rng = np.random.default_rng(seed + 3)
    needs, prompts = [], []
    while sum(needs) <= int8_budget - 1:         # one past the int8 pool
        long = len(needs) % 4 == 3
        needs.append(long_need if long else short_need)
        plen = (long_bucket if long else short_bucket) - max_new
        prompts.append(rng.integers(0, qcfg.vocab_size, plen)
                       .astype(np.int32))
    n_req = len(prompts)

    def _first_wave(usable):
        """Head-of-line admission: requests admitted before the pool
        first blocks (everything finishes together afterwards, so this
        IS the engine's max_active)."""
        used = active = 0
        for nd in needs:
            if used + nd > usable:
                break
            used, active = used + nd, active + 1
        return active

    expect = {"bfloat16": _first_wave(bf16_budget - 1),
              "int8": _first_wave(int8_budget - 1)}

    engines, tok_s = {}, {}
    for name, rt_e, budget in (("bfloat16", rt_ref, bf16_budget),
                               ("int8", rt_q, int8_budget)):
        eng = PagedServeEngine(params, qcfg, rt_e, n_slots=n_req,
                               max_len=window, page_size=page_size,
                               page_budget=budget, prefix_cache=False)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p.copy(),
                               max_new_tokens=max_new))
        t0 = time.perf_counter()
        eng.run(max_iters=5000)
        wall = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in eng.finished)
        tok_s[name] = toks / wall if wall > 0 else float("nan")
        engines[name] = eng
    bf16, int8 = engines["bfloat16"], engines["int8"]

    # accuracy sidebar over the same prompt mix (2 shorts + the long)
    parity = logit_parity(params, qcfg, prompts[1:4], rt_ref=rt_ref,
                          rt_test=rt_q, max_new_tokens=6)

    bf16_bytes, int8_bytes = bf16.kv_cache_bytes(), int8.kv_cache_bytes()
    ratio = (int8.stats.max_active / bf16.stats.max_active
             if bf16.stats.max_active else float("nan"))
    row = {
        "trace": "quantized_kv", "window": window,
        "page_size": page_size, "head_dim": qcfg.head_dim,
        "requests": n_req, "max_new": max_new,
        "page_budget_bf16": bf16_budget, "page_budget_int8": int8_budget,
        "kv_hbm_bytes_bf16": bf16_bytes, "kv_hbm_bytes_int8": int8_bytes,
        "max_active_bf16": bf16.stats.max_active,
        "max_active_int8": int8.stats.max_active,
        "max_active_ratio": ratio,
        "kv_utilization_bf16": bf16.stats.kv_utilization,
        "kv_utilization_int8": int8.stats.kv_utilization,
        "tok_s_bf16": tok_s["bfloat16"], "tok_s_int8": tok_s["int8"],
        "parity": parity.to_json(),
    }
    done_ok = all(len(e.finished) == n_req and not e.rejected
                  for e in engines.values())
    # the int8 pool must land on the bf16 pool's bytes (scale side-bands
    # included), never above it beyond the page-granularity round-off
    hbm_ok = int8_bytes <= bf16_bytes * 1.02 + 1 \
        and int8_bytes >= bf16_bytes * 0.90
    admit_ok = (bf16.stats.max_active == expect["bfloat16"]
                and int8.stats.max_active == expect["int8"])
    ok = (done_ok and hbm_ok and admit_ok and parity.within_tol
          and ratio >= 1.8)
    return row, ok


def _prefix_trace(params, cfg, rt, *, window: int, page_size: int,
                  n_requests: int, max_new: int, seed: int):
    """Repeated-system-prompt trace, cold vs warm prefix cache; returns
    (row, ok)."""
    from repro.serve import PagedServeEngine, Request

    rng = np.random.default_rng(seed + 2)
    sys_len = page_size * max(2, window // (4 * page_size))
    sys_prompt = rng.integers(0, cfg.vocab_size, sys_len).astype(np.int32)
    prompts = []
    for _ in range(n_requests):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, max(5, window // 8))))
        prompts.append(np.concatenate([sys_prompt,
                                       tail.astype(np.int32)]))

    engines = {}
    for mode, on in (("cold", False), ("warm", True)):
        eng = PagedServeEngine(params, cfg, rt, n_slots=4, max_len=window,
                               page_size=page_size, prefix_cache=on)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p.copy(),
                               max_new_tokens=max_new))
        eng.run(max_iters=5000)
        engines[mode] = eng
    cold, warm = engines["cold"], engines["warm"]

    parity = _finished_tokens(cold) == _finished_tokens(warm)
    hit_rate = warm.prefix_hit_rate
    row = {
        "trace": "repeated_prefix", "window": window,
        "page_size": page_size, "requests": n_requests,
        "system_prompt_tokens": sys_len,
        "prefix_hit_rate": hit_rate,
        "prefix_hits": warm.stats.prefix_hits,
        "prefix_hit_tokens": warm.stats.prefix_hit_tokens,
        "prefill_tokens_cold": cold.stats.prefill_tokens,
        "prefill_tokens_warm": warm.stats.prefill_tokens,
        "prefill_calls_cold": cold.stats.prefills,
        "prefill_calls_warm": warm.stats.prefills,
        "prefill_compiles_cold": cold.stats.prefill_compiles,
        "prefill_compiles_warm": warm.stats.prefill_compiles,
        "kv_utilization_warm": warm.stats.kv_utilization,
        "token_parity": parity,
    }
    ok = (parity and warm.stats.prefix_hits > 0 and hit_rate > 0
          and warm.stats.prefill_tokens < cold.stats.prefill_tokens
          and warm.stats.prefills < cold.stats.prefills)
    return row, ok


def _scenario_replay(params, cfg, rt, *, scenario_name: str, n_slots: int,
                     max_len: int, page_size: int, n_requests: int,
                     seed: int):
    """Replay a named traffic scenario through the live paged engine and
    check bound soundness: the static per-token p50 *lower bound* from
    ``deploy_preflight`` (service time only, zero queueing) must sit at
    or below the measured p50 on the same spec; returns (row, ok)."""
    from repro.analysis.deploy_lint import DeploymentSpec, deploy_preflight
    from repro.serve import PagedServeEngine, Request
    from repro.serve.scenarios import get_scenario

    scen = get_scenario(scenario_name).scaled(max_len)
    dep = DeploymentSpec(n_slots=n_slots, max_len=max_len,
                         page_size=page_size, dtype="float32",
                         param_dtype="float32")
    rep = deploy_preflight(cfg, scen, deployment=dep)

    eng = PagedServeEngine(params, cfg, rt, n_slots=n_slots,
                           max_len=max_len, page_size=page_size)
    trace = scen.sample_requests(n_requests, seed=seed)
    rng = np.random.default_rng(seed + 4)
    prompts = {i: rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
               for i, (_, plen, _) in enumerate(trace)}
    token_lat = []
    t0 = time.perf_counter()
    i_next = 0
    while i_next < len(trace) or eng.queue \
            or any(s is not None for s in eng.slots):
        now = time.perf_counter() - t0
        while i_next < len(trace) and trace[i_next][0] <= now:
            _, _, olen = trace[i_next]
            eng.submit(Request(rid=i_next, prompt=prompts[i_next],
                               max_new_tokens=olen))
            i_next += 1
        if not (eng.queue or any(s is not None for s in eng.slots)):
            time.sleep(min(trace[i_next][0] - now, 0.05)
                       if i_next < len(trace) else 0)
            continue
        before = eng.stats.tokens_out
        t1 = time.perf_counter()
        eng.step()
        dt = time.perf_counter() - t1
        token_lat.extend([dt] * (eng.stats.tokens_out - before))
    lat = np.asarray(token_lat) * 1e3
    p50 = float(np.percentile(lat, 50)) if len(lat) else float("nan")
    p99 = float(np.percentile(lat, 99)) if len(lat) else float("nan")
    sound = bool(rep.tok_p50_lb_ms <= p50)
    row = {
        "trace": "scenario_replay", "scenario": scen.name,
        "requests": len(trace), "served": len(eng.finished),
        "rate_req_s": scen.arrival.rate_rps,
        "measured_p50_token_ms": p50, "measured_p99_token_ms": p99,
        "static_p50_lb_ms": rep.tok_p50_lb_ms,
        "static_p99_lb_ms": rep.tok_p99_lb_ms,
        "static_ttft_lb_ms": rep.ttft_lb_ms,
        "rho": rep.rho, "rho_peak": rep.rho_peak,
        "best_batch": rep.best_batch,
        "deploy_findings": [f.rule_id for f in rep.findings],
        "bound_sound": sound,
    }
    ok = (sound and len(eng.finished) == len(trace)
          and not eng.rejected
          and not any(f.severity == "error" for f in rep.findings))
    return row, ok


def run(arch: str = "minicpm-2b", n_requests: int = 24, n_slots: int = 4,
        max_len: int = 128, max_new: int = 12, seed: int = 0,
        load: float = 0.8, rate: Optional[float] = None,
        page_size: int = 16, mixed_max_len: int = 512,
        mixed_requests: Optional[int] = None,
        prefix_requests: int = 6):
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.models import init_params
    from repro.models.model import ModelRuntime
    from repro.serve import Request, ServeEngine

    cfg = smoke_config(ARCHS[arch])
    rt = ModelRuntime(dtype="float32", remat="none", attn_chunk=32,
                      moe_dropless=True)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    eng = ServeEngine(params, cfg, rt, n_slots=n_slots, max_len=max_len)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, max_len // 4)))
               .astype(np.int32) for _ in range(n_requests)]

    # -- warmup: compile the prefill buckets + decode step off the clock,
    # then time a second (compile-free) request for the service-rate
    # estimate the arrival process is calibrated against
    eng.submit(Request(rid=-1, prompt=prompts[0], max_new_tokens=4))
    eng.run()
    warm = time.perf_counter()
    steps0 = eng.stats.steps
    eng.submit(Request(rid=-2, prompt=prompts[0], max_new_tokens=4))
    eng.run()
    eng.finished.clear()
    warm_steps = max(eng.stats.steps - steps0, 1)
    step_s_est = max((time.perf_counter() - warm) / warm_steps, 1e-5)
    # occupancy must describe the measured trace, not the warmup
    trace_steps0 = eng.stats.steps
    trace_occ0 = eng.stats.occupancy_sum

    # -- open-loop arrival trace: exponential inter-arrivals at `load` x
    # the engine's rough service rate (requests/s), independent of
    # completions — the arrival process never waits on the engine.
    if rate is None:
        svc = n_slots / (max_new * step_s_est)   # ~requests/s capacity
        rate = max(load * svc, 1e-3)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))

    token_lat, req_done_t = [], {}
    t0 = time.perf_counter()
    i_next, n_finished_seen = 0, 0
    submit_t = {}
    while i_next < n_requests or eng.queue \
            or any(s is not None for s in eng.slots):
        now = time.perf_counter() - t0
        while i_next < n_requests and arrivals[i_next] <= now:
            eng.submit(Request(rid=i_next, prompt=prompts[i_next],
                               max_new_tokens=max_new))
            submit_t[i_next] = now
            i_next += 1
        busy = eng.queue or any(s is not None for s in eng.slots)
        if not busy:
            time.sleep(min(arrivals[i_next] - now, 0.05)
                       if i_next < n_requests else 0)
            continue
        before = eng.stats.tokens_out
        t1 = time.perf_counter()
        eng.step()
        dt = time.perf_counter() - t1
        emitted = eng.stats.tokens_out - before
        token_lat.extend([dt] * emitted)
        for r in eng.finished[n_finished_seen:]:
            req_done_t[r.rid] = time.perf_counter() - t0
        n_finished_seen = len(eng.finished)
    wall = time.perf_counter() - t0

    done = eng.finished
    toks = sum(len(r.out_tokens) for r in done)
    tok_s = toks / wall if wall > 0 else float("nan")
    lat = np.asarray(token_lat) * 1e3
    req_lat = np.asarray([req_done_t[r.rid] - submit_t[r.rid]
                          for r in done if r.rid in submit_t])
    trace_steps = eng.stats.steps - trace_steps0
    occupancy = ((eng.stats.occupancy_sum - trace_occ0)
                 / (trace_steps * n_slots)) if trace_steps else 0.0
    mean_ctx = int(np.mean([len(p) for p in prompts]) + max_new / 2)
    wl, pred_rows = _predictions(cfg, n_slots, max(mean_ctx, 1), tok_s)

    rows = [{
        "arch": cfg.name, "trace": "open_loop", "requests": len(done),
        "tokens": toks,
        "wall_s": wall, "tok_s": tok_s, "rate_req_s": rate,
        "p50_token_ms": float(np.percentile(lat, 50)) if len(lat) else None,
        "p99_token_ms": float(np.percentile(lat, 99)) if len(lat) else None,
        "p50_req_s": float(np.percentile(req_lat, 50)) if len(req_lat)
        else None,
        "p99_req_s": float(np.percentile(req_lat, 99)) if len(req_lat)
        else None,
        "occupancy": occupancy,
        "kv_utilization": eng.stats.kv_utilization,
        "kv_hbm_bytes": eng.kv_cache_bytes(),
        "max_active": eng.stats.max_active,
        "prefill_compiles": eng.stats.prefill_compiles,
        "compile_bound": eng.scheduler.max_prefill_compiles(),
        "rejected": len(eng.rejected),
        "workload": wl.name,
    }]

    # -- paged-KV headline traces (closed-loop, seeded, token parity)
    mixed_n = mixed_requests if mixed_requests is not None \
        else max(8, min(16, n_requests))
    paged_row, paged_ok = _paged_vs_fixed(
        params, cfg, rt, n_slots=n_slots, window=mixed_max_len,
        page_size=page_size, n_requests=mixed_n, max_new=max_new,
        seed=seed)
    rows.append(paged_row)
    prefix_row, prefix_ok = _prefix_trace(
        params, cfg, rt, window=mixed_max_len, page_size=page_size,
        n_requests=prefix_requests, max_new=max_new, seed=seed)
    rows.append(prefix_row)
    quant_row, quant_ok = _quantized_kv_trace(
        cfg, window=mixed_max_len, page_size=page_size, base_slots=3,
        max_new=max_new, seed=seed)
    rows.append(quant_row)
    scen_row, scen_ok = _scenario_replay(
        params, cfg, rt, scenario_name="chat_burst", n_slots=n_slots,
        max_len=max_len, page_size=page_size,
        n_requests=min(12, n_requests), seed=seed)
    rows.append(scen_row)

    emit("serve_throughput", rows)
    if pred_rows:
        emit("serve_throughput_predictions", pred_rows)

    ok = (len(done) == n_requests and toks == n_requests * max_new
          and not eng.rejected and np.isfinite(tok_s)
          and len(pred_rows) >= 1
          and eng.stats.prefill_compiles
          <= eng.scheduler.max_prefill_compiles()
          and paged_ok and prefix_ok and quant_ok and scen_ok)
    print(f"[serve/{cfg.name}] {len(done)} reqs, {toks} tokens, "
          f"{tok_s:.1f} tok/s, p50/p99 token "
          f"{rows[0]['p50_token_ms']:.1f}/{rows[0]['p99_token_ms']:.1f} "
          f"ms, occupancy {occupancy:.2f}, "
          f"{eng.stats.prefill_compiles} prefill compiles "
          f"(bound {eng.scheduler.max_prefill_compiles()}); "
          f"{len(pred_rows)} prediction row(s)")
    print(f"[serve/paged] equal-HBM mixed trace: max_active "
          f"{paged_row['max_active_paged']} paged vs "
          f"{paged_row['max_active_fixed']} fixed (n_slots={n_slots}), "
          f"kv_util {paged_row['kv_utilization_paged']:.2f} vs "
          f"{paged_row['kv_utilization_fixed']:.2f}, "
          f"parity={paged_row['token_parity']}")
    print(f"[serve/prefix] hit_rate={prefix_row['prefix_hit_rate']:.2f} "
          f"prefill_tokens {prefix_row['prefill_tokens_warm']} warm vs "
          f"{prefix_row['prefill_tokens_cold']} cold, "
          f"parity={prefix_row['token_parity']}")
    print(f"[serve/quant] equal-HBM int8-KV trace: max_active "
          f"{quant_row['max_active_int8']} int8 vs "
          f"{quant_row['max_active_bf16']} bf16 "
          f"({quant_row['max_active_ratio']:.2f}x), kv bytes "
          f"{quant_row['kv_hbm_bytes_int8']} vs "
          f"{quant_row['kv_hbm_bytes_bf16']}, max_logit_dev "
          f"{quant_row['parity']['max_logit_dev']:.4f} "
          f"(tol {quant_row['parity']['tol']}), token_match "
          f"{quant_row['parity']['token_match_frac']:.2f}")
    print(f"[serve/scenario] {scen_row['scenario']}: measured p50 "
          f"{scen_row['measured_p50_token_ms']:.2f} ms vs static lower "
          f"bound {scen_row['static_p50_lb_ms']:.4f} ms "
          f"(sound={scen_row['bound_sound']}), rho={scen_row['rho']:.3f} "
          f"at batch={scen_row['best_batch']}, served "
          f"{scen_row['served']}/{scen_row['requests']}")
    return {"tok_s": tok_s, "p50_token_ms": rows[0]["p50_token_ms"],
            "p99_token_ms": rows[0]["p99_token_ms"],
            "occupancy": occupancy, "requests": len(done),
            "kv_utilization": rows[0]["kv_utilization"],
            "kv_hbm_bytes": rows[0]["kv_hbm_bytes"],
            "max_active_paged": paged_row["max_active_paged"],
            "max_active_fixed": paged_row["max_active_fixed"],
            "paged_token_parity": paged_row["token_parity"],
            "kv_utilization_paged": paged_row["kv_utilization_paged"],
            "max_active_int8": quant_row["max_active_int8"],
            "max_active_bf16_paged": quant_row["max_active_bf16"],
            "quant_max_active_ratio": quant_row["max_active_ratio"],
            "quant_max_logit_dev": quant_row["parity"]["max_logit_dev"],
            "quant_token_match_frac":
            quant_row["parity"]["token_match_frac"],
            "prefix_hit_rate": prefix_row["prefix_hit_rate"],
            "prefix_prefill_tokens_saved":
            prefix_row["prefill_tokens_cold"]
            - prefix_row["prefill_tokens_warm"],
            "scenario": scen_row["scenario"],
            "scenario_p50_token_ms": scen_row["measured_p50_token_ms"],
            "scenario_static_p50_lb_ms": scen_row["static_p50_lb_ms"],
            "scenario_bound_sound": scen_row["bound_sound"],
            "predicted_tok_s": pred_rows[0]["predicted_tok_s"]
            if pred_rows else None,
            "measured_over_predicted":
            pred_rows[0]["measured_over_predicted"] if pred_rows else None,
            "pass": bool(ok)}


if __name__ == "__main__":
    run()
