"""§Serving throughput: a synthetic open-loop arrival trace through the
live ServeEngine, cross-checked against the analytical models.

The paper's loop is *benchmark the accelerator against the targeted
workload, then compare the analytical prediction to the measurement*
(Figs. 4/5: 1.15%/2.17% model error). Serving is the one live workload
this repo runs end-to-end, so this benchmark closes that loop for it:

* **measured** — a seeded open-loop trace (exponential inter-arrivals,
  arrivals never wait on completions) is driven through the engine on
  this host; we report tok/s, p50/p99 per-token latency (each decode
  step's wall time attributed to the tokens it emitted), request
  latency percentiles, and mean slot occupancy.
* **predicted** — the *same* serving workload expressed in the Workload
  IR (``lm_workload`` decode profile at the engine's slot batch and
  mean live context) evaluated by ``TPUModel`` (analytic, v5e) and —
  when a kernel calibration exists — ``MeasuredModel``; the row pairs
  each prediction with the measured tok/s.

On a CPU CI host the absolute ratio is meaningless (the prediction
targets a TPU); the contract here is the *schema*: every run emits the
measured metrics plus a predicted-vs-measured throughput row into
``artifacts/bench/results.json``.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import emit


def _predictions(cfg, n_slots: int, mean_ctx: int, measured_tok_s: float):
    """Predicted serving throughput rows from the analytical models for
    the engine's decode workload (one token per slot per step)."""
    from repro.configs.base import ShapeConfig
    from repro.core.analytical.interface import DesignPoint
    from repro.core.analytical.tpu_model import TPUModel
    from repro.core.workload import lm_workload

    shape = ShapeConfig("serve_decode", seq_len=mean_ctx,
                        global_batch=n_slots, kind="decode",
                        kv_len=mean_ctx)
    wl = lm_workload(cfg, shape)
    rows = []
    point = DesignPoint.make(sp=0, log2_m=0, front_is=0, tail_is=0)
    r = TPUModel(cfg, shape, dp=1, model_axis=1, pods=1,
                 workload=wl).evaluate(point)
    if r.feasible:
        pred = n_slots / r.latency_s
        rows.append({"model": "tpu_v5e_analytic",
                     "predicted_tok_s": pred,
                     "measured_tok_s": measured_tok_s,
                     "measured_over_predicted": measured_tok_s / pred})
    try:
        from repro.core.analytical.measured import (CalibrationMissing,
                                                    MeasuredModel)
        try:
            m = MeasuredModel(wl).evaluate(DesignPoint.make())
            if m.feasible:
                pred = n_slots / m.latency_s
                rows.append({"model": "measured_calibration",
                             "predicted_tok_s": pred,
                             "measured_tok_s": measured_tok_s,
                             "measured_over_predicted":
                                 measured_tok_s / pred})
        except CalibrationMissing:
            pass                    # optional anchor; analytic row stands
    except ImportError:
        pass
    return wl, rows


def run(arch: str = "minicpm-2b", n_requests: int = 24, n_slots: int = 4,
        max_len: int = 128, max_new: int = 12, seed: int = 0,
        load: float = 0.8, rate: Optional[float] = None):
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.models import init_params
    from repro.models.model import ModelRuntime
    from repro.serve import Request, ServeEngine

    cfg = smoke_config(ARCHS[arch])
    rt = ModelRuntime(dtype="float32", remat="none", attn_chunk=32,
                      moe_dropless=True)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    eng = ServeEngine(params, cfg, rt, n_slots=n_slots, max_len=max_len)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, max_len // 4)))
               .astype(np.int32) for _ in range(n_requests)]

    # -- warmup: compile the prefill buckets + decode step off the clock,
    # then time a second (compile-free) request for the service-rate
    # estimate the arrival process is calibrated against
    eng.submit(Request(rid=-1, prompt=prompts[0], max_new_tokens=4))
    eng.run()
    warm = time.perf_counter()
    steps0 = eng.stats.steps
    eng.submit(Request(rid=-2, prompt=prompts[0], max_new_tokens=4))
    eng.run()
    eng.finished.clear()
    warm_steps = max(eng.stats.steps - steps0, 1)
    step_s_est = max((time.perf_counter() - warm) / warm_steps, 1e-5)
    # occupancy must describe the measured trace, not the warmup
    trace_steps0 = eng.stats.steps
    trace_occ0 = eng.stats.occupancy_sum

    # -- open-loop arrival trace: exponential inter-arrivals at `load` x
    # the engine's rough service rate (requests/s), independent of
    # completions — the arrival process never waits on the engine.
    if rate is None:
        svc = n_slots / (max_new * step_s_est)   # ~requests/s capacity
        rate = max(load * svc, 1e-3)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))

    token_lat, req_done_t = [], {}
    t0 = time.perf_counter()
    i_next, n_finished_seen = 0, 0
    submit_t = {}
    while i_next < n_requests or eng.queue \
            or any(s is not None for s in eng.slots):
        now = time.perf_counter() - t0
        while i_next < n_requests and arrivals[i_next] <= now:
            eng.submit(Request(rid=i_next, prompt=prompts[i_next],
                               max_new_tokens=max_new))
            submit_t[i_next] = now
            i_next += 1
        busy = eng.queue or any(s is not None for s in eng.slots)
        if not busy:
            time.sleep(min(arrivals[i_next] - now, 0.05)
                       if i_next < n_requests else 0)
            continue
        before = eng.stats.tokens_out
        t1 = time.perf_counter()
        eng.step()
        dt = time.perf_counter() - t1
        emitted = eng.stats.tokens_out - before
        token_lat.extend([dt] * emitted)
        for r in eng.finished[n_finished_seen:]:
            req_done_t[r.rid] = time.perf_counter() - t0
        n_finished_seen = len(eng.finished)
    wall = time.perf_counter() - t0

    done = eng.finished
    toks = sum(len(r.out_tokens) for r in done)
    tok_s = toks / wall if wall > 0 else float("nan")
    lat = np.asarray(token_lat) * 1e3
    req_lat = np.asarray([req_done_t[r.rid] - submit_t[r.rid]
                          for r in done if r.rid in submit_t])
    trace_steps = eng.stats.steps - trace_steps0
    occupancy = ((eng.stats.occupancy_sum - trace_occ0)
                 / (trace_steps * n_slots)) if trace_steps else 0.0
    mean_ctx = int(np.mean([len(p) for p in prompts]) + max_new / 2)
    wl, pred_rows = _predictions(cfg, n_slots, max(mean_ctx, 1), tok_s)

    rows = [{
        "arch": cfg.name, "requests": len(done), "tokens": toks,
        "wall_s": wall, "tok_s": tok_s, "rate_req_s": rate,
        "p50_token_ms": float(np.percentile(lat, 50)) if len(lat) else None,
        "p99_token_ms": float(np.percentile(lat, 99)) if len(lat) else None,
        "p50_req_s": float(np.percentile(req_lat, 50)) if len(req_lat)
        else None,
        "p99_req_s": float(np.percentile(req_lat, 99)) if len(req_lat)
        else None,
        "occupancy": occupancy,
        "prefill_compiles": eng.stats.prefill_compiles,
        "compile_bound": eng.scheduler.max_prefill_compiles(),
        "rejected": len(eng.rejected),
        "workload": wl.name,
    }]
    emit("serve_throughput", rows)
    if pred_rows:
        emit("serve_throughput_predictions", pred_rows)

    ok = (len(done) == n_requests and toks == n_requests * max_new
          and not eng.rejected and np.isfinite(tok_s)
          and len(pred_rows) >= 1
          and eng.stats.prefill_compiles
          <= eng.scheduler.max_prefill_compiles())
    print(f"[serve/{cfg.name}] {len(done)} reqs, {toks} tokens, "
          f"{tok_s:.1f} tok/s, p50/p99 token "
          f"{rows[0]['p50_token_ms']:.1f}/{rows[0]['p99_token_ms']:.1f} "
          f"ms, occupancy {occupancy:.2f}, "
          f"{eng.stats.prefill_compiles} prefill compiles "
          f"(bound {eng.scheduler.max_prefill_compiles()}); "
          f"{len(pred_rows)} prediction row(s)")
    return {"tok_s": tok_s, "p50_token_ms": rows[0]["p50_token_ms"],
            "p99_token_ms": rows[0]["p99_token_ms"],
            "occupancy": occupancy, "requests": len(done),
            "predicted_tok_s": pred_rows[0]["predicted_tok_s"]
            if pred_rows else None,
            "measured_over_predicted":
            pred_rows[0]["measured_over_predicted"] if pred_rows else None,
            "pass": bool(ok)}


if __name__ == "__main__":
    run()
