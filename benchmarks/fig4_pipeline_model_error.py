"""Fig. 4 reproduction: pipeline (paradigm 1) analytic model vs the
cycle-approximate event simulator (the board stand-in).

Paper: avg 1.15% error between estimated and board-level performance
across AlexNet/ZF/VGG16/YOLO at 16- and 8-bit on ZC706 + KU115.

Workloads come from the registry (CNN front-end of the Workload IR).
"""
from __future__ import annotations

from repro.core.analytical.pipeline import pipeline_performance
from repro.core.hardware import KU115, ZC706
from repro.core.workload import get_workload
from repro.sim.simulator import simulate_pipeline

from benchmarks.common import emit

# (a) ZC706: N1-N3 = AlexNet/ZF/YOLO @16b, N4-N6 same @8b
# (b) KU115: N1-N4 = AlexNet/ZF/VGG16/YOLO @16b, N5-N8 same @8b
CASES = []
for bits in (16, 8):
    for nm, sz in (("alexnet", 224), ("zf", 224), ("yolo", 448)):
        CASES.append(("ZC706", ZC706, nm, sz, bits))
    for nm, sz in (("alexnet", 224), ("zf", 224), ("vgg16", 224),
                   ("yolo", 448)):
        CASES.append(("KU115", KU115, nm, sz, bits))


def run(batch: int = 2):
    rows = []
    for board, spec, nm, sz, bits in CASES:
        wl = get_workload(nm, input_size=sz, abits=bits, wbits=bits)
        d = pipeline_performance(wl, spec, batch=batch,
                                 wbits=bits, abits=bits)
        if not d.feasible:
            continue
        s = simulate_pipeline(d, spec)
        err = (d.gops() - s.gops) / s.gops * 100
        rows.append({"board": board, "net": nm, "bits": bits,
                     "analytic_gops": d.gops(), "sim_gops": s.gops,
                     "err_pct": err})
    avg = sum(abs(r["err_pct"]) for r in rows) / len(rows)
    rows.append({"board": "AVG", "net": "-", "bits": "-",
                 "analytic_gops": "-", "sim_gops": "-", "err_pct": avg})
    emit("fig4_pipeline_model_error", rows)
    print(f"[fig4] avg |err| = {avg:.2f}%  (paper: 1.15%)")
    return {"avg_err_pct": avg, "paper_err_pct": 1.15,
            "pass": avg <= 3.0}


if __name__ == "__main__":
    run()
