"""Fig. 6 reproduction: CTC (computation-to-communication) distribution
of VGG-16 CONV layers across 12 input resolutions.

Paper: CTC medians rise ~256x from 32x32 to 512x512 inputs.

``Workload.ctc_stats`` (the IR's per-op CTC) replaces the old
free-standing helper over ConvLayer lists.
"""
from __future__ import annotations

from repro.core.workload import INPUT_SIZE_CASES, get_workload

from benchmarks.common import emit


def run():
    rows = []
    for sz in INPUT_SIZE_CASES:
        stats = get_workload("vgg16", input_size=sz).ctc_stats()
        rows.append({"input": sz, **stats})
    growth = rows[-1]["median"] / rows[0]["median"]
    emit("fig6_ctc", rows)
    print(f"[fig6] CTC median growth 32->512: {growth:.1f}x "
          f"(paper: ~256x)")
    return {"median_growth": growth, "paper_growth": 256.0,
            "pass": 128.0 <= growth <= 512.0}


if __name__ == "__main__":
    run()
