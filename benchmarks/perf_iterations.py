"""§Perf hillclimb: hypothesis -> change -> re-lower -> validate, on the
three selected cells (worst roofline fraction / most collective-bound /
most representative of the paper's technique).

Each variant is a full dry-run lowering (same machinery as the baseline
sweep); the log records the napkin-math prediction and whether the
measured artifact confirmed it. Run AFTER the baseline sweep:

    PYTHONPATH=src python -m benchmarks.perf_iterations
"""
import json
import os

from benchmarks.common import emit

# Cells: (arch, shape, why picked)
CELLS = [
    ("mixtral-8x22b", "train_4k",
     "most representative: MoE + EP + the DSE's own recipe space; "
     "collective-heavy baseline"),
    ("qwen2-moe-a2.7b", "prefill_32k",
     "worst roofline fraction (useful ratio 3e-4): einsum dispatch "
     "at T=1M tokens, 60 experts"),
    ("minicpm-2b", "train_4k",
     "most collective-bound dense cell (coll/compute ~6x); prime vocab "
     "122753 defeats lm_head sharding, 36 heads defeat head-TP"),
]


def _pad_vocab(mult: int = 256):
    def tf(cfg):
        v = -(-cfg.vocab_size // mult) * mult
        return cfg.replace(vocab_size=v)
    return tf


def _capshard_recipe(kind: str):
    """Shard the MoE capacity dim over `data`: the dispatch einsum's
    token-contraction then produces data-sharded expert buffers
    (reduce-scatter semantics) instead of replicated ones (all-reduce),
    and the expert GEMMs shard over data x model."""
    from repro.dist.sharding import IS_RECIPE, WS_RECIPE
    base = IS_RECIPE if kind == "train" else WS_RECIPE
    return base.with_rules(capacity=("data",)).replace_name(
        base.name + "+capshard")


def _seqres_recipe():
    """Megatron-SP: keep the residual stream sequence-sharded over
    `model` between layers — the per-layer TP all-reduces become
    reduce-scatter + all-gather halves around each block."""
    from repro.dist.sharding import IS_SEQ_RECIPE
    return IS_SEQ_RECIPE.with_rules(seq="model").replace_name(
        "is-seqattn+seqres")


# Per-cell variant ladder: (name, hypothesis, lower_cell kwargs)
VARIANTS = {
    ("mixtral-8x22b", "train_4k"): [
        ("moe_chunk2048",
         "GShard token groups Tc=2048: dispatch/combine einsums are "
         "O(T*E*C*d) with C~K*T/E=16k; per-group C=640 cuts them ~25x. "
         "Predict compute 92.8s -> ~15s, memory & collectives down "
         "several-fold (no (T,E,16k) tensors).",
         dict(moe_chunk=2048)),
        ("moe_chunk512",
         "Smaller groups (Tc=512, C=160): dispatch cost down another 4x "
         "but more dropping variance; predict small further compute win.",
         dict(moe_chunk=512)),
        ("moe_chunk2048_m8",
         "Halve grad-accum M 16->8 on top of Tc=2048: IS weight "
         "all-gathers per step halve; predict collective term ~-40%, "
         "HBM carries x2 (analytic footprint still fits).",
         dict(moe_chunk=2048, microbatches=8)),
        ("moe_chunk512_capshard",
         "Iter 2 (collective-bound, 8 TB all-reduce/chip): the dispatch "
         "psum over data replicates (E,C,d) buffers on every chip. "
         "Shard capacity over data -> reduce-scatter semantics + "
         "data-sharded expert GEMMs. Predict all-reduce bytes ~-8x and "
         "a further compute shard.",
         dict(moe_chunk=512, recipe="capshard")),
    ],
    ("qwen2-moe-a2.7b", "prefill_32k"): [
        ("moe_chunk2048",
         "Tc=2048 at T=1M, E=60: C 87k -> 171, dispatch ~512x cheaper. "
         "Predict compute 353s -> ~1-2s (expert math + attention left).",
         dict(moe_chunk=2048)),
        ("moe_chunk4096",
         "Tc=4096 (C=342): half the groups, 2x dispatch cost vs Tc=2048 "
         "but less routing variance; predict compute slightly higher.",
         dict(moe_chunk=4096)),
        ("moe_chunk2048_capshard",
         "Iter 2: same dispatch-psum story as mixtral — capacity over "
         "data. Predict the 12.1s collective term drops several-fold.",
         dict(moe_chunk=2048, recipe="capshard")),
    ],
    ("minicpm-2b", "train_4k"): [
        ("vocab_pad",
         "Pad vocab 122753 -> 122880 (%256==0): lm_head/embed/logits "
         "shard 16x over model instead of replicating. Predict the "
         "replicated 2*T*d*V lm_head flops (~10%) shard away and the "
         "f32 logits buffer leaves the memory term.",
         dict(cfg_transform=_pad_vocab())),
        ("vocab_pad_m4",
         "M 8->4 on top: halve per-step weight all-gather rounds; "
         "predict collective ~-40%, carries x2 (fits: 2.7B model).",
         dict(cfg_transform=_pad_vocab(), microbatches=4)),
        ("vocab_pad_dots",
         "remat full->dots on top of vocab_pad: no fwd recompute, "
         "predict compute -25%, memory carries grow (fits).",
         dict(cfg_transform=_pad_vocab(), remat="dots")),
        ("vocab_pad_m4_seqres",
         "Iter 2 (collective-bound, 353 GB all-reduce/chip): "
         "sequence-shard the residual stream over `model` (Megatron-SP) "
         "so per-layer TP all-reduces become RS+AG halves. Predict "
         "all-reduce bytes ~-2x.",
         dict(cfg_transform=_pad_vocab(), microbatches=4,
              recipe="seqres")),
    ],
}


def _analytic_memory_s(art):
    """TPU-side memory term from the analytic model (the CPU backend's
    ``bytes_accessed`` is fusion-pessimistic by ~2 orders of magnitude —
    e.g. mixtral train baseline: 220 s would mean 180 TB/chip/step).
    Compute and collective terms stay *measured* (HLO op counts are
    reliable); only the memory term is substituted."""
    from repro.core.analytical.tpu_model import analyze
    from repro.core.workload import lm_workload
    from repro.launch.presets import get_preset

    from benchmarks.roofline_table import plan_from_artifact

    pset = get_preset(art.get("preset", "full"))
    cfg = pset.arch(art["arch"])
    shape = pset.shape(art["shape"])
    wl = lm_workload(cfg, shape)
    return analyze(wl, plan_from_artifact(cfg, shape, art)).memory_s


def summarize(art):
    if art["status"] != "OK":
        return {"status": art["status"],
                "err": art.get("error", "")[:80]}
    r = art["roofline"]
    mem_an = _analytic_memory_s(art)
    adj = max(r["compute_s"], r["collective_s"], mem_an)
    mf = r["model_flops"]
    chips = art.get("devices", 256)
    frac_adj = (mf / adj) / (chips * 197e12) if adj > 0 else 0.0
    return {
        "status": "OK",
        "compute_s": round(r["compute_s"], 4),
        "memory_s": round(r["memory_s"], 4),
        "mem_analytic_s": round(mem_an, 4),
        "collective_s": round(r["collective_s"], 4),
        "dominant": r["dominant"],
        "useful_ratio": round(r["useful_flops_ratio"], 5),
        "roofline_frac": round(r["roofline_fraction"], 5),
        "bound_s": round(r["step_time_bound_s"], 4),
        "adj_bound_s": round(adj, 4),
        "adj_frac": round(frac_adj, 5),
    }


def run(mesh_name: str = "single", preset_name: str = "full"):
    from repro.artifacts import cell_path, perf_dir
    from repro.launch.lowering import lower_cell
    from repro.launch.presets import get_preset

    preset = get_preset(preset_name)
    mesh = preset.build_mesh(mesh_name)
    out_dir = perf_dir()
    os.makedirs(out_dir, exist_ok=True)
    log = []
    for arch, shape, why in CELLS:
        base_path = cell_path(preset_name, arch, shape, mesh_name)
        with open(base_path) as f:
            base = json.load(f)
        best = summarize(base)
        best_name = "baseline"
        log.append({"cell": f"{arch}/{shape}", "variant": "baseline",
                    "hypothesis": f"(picked because: {why})", **best})
        print(f"\n### {arch} x {shape} — {why}")
        print(f"  baseline: {best}")
        for name, hyp, kw in VARIANTS[(arch, shape)]:
            tag = f"{arch}__{shape}__{mesh_name}__{name}"
            path = os.path.join(out_dir, tag + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    art = json.load(f)
            else:
                kw2 = dict(kw)
                if kw2.get("recipe") == "capshard":
                    from repro.configs import get_shape as _gs
                    kw2["recipe"] = _capshard_recipe(_gs(shape).kind)
                elif kw2.get("recipe") == "seqres":
                    kw2["recipe"] = _seqres_recipe()
                art = lower_cell(arch, shape, mesh, mesh_name,
                                 preset=preset, variant=name, **kw2)
                with open(path, "w") as f:
                    json.dump(art, f, indent=1, default=str)
            s = summarize(art)
            verdict = "?"
            if s["status"] == "OK" and best["status"] == "OK":
                verdict = ("CONFIRMED"
                           if s["adj_bound_s"] < best["adj_bound_s"]
                           else "REFUTED")
                if s["adj_bound_s"] < best["adj_bound_s"]:
                    best, best_name = s, name
            log.append({"cell": f"{arch}/{shape}", "variant": name,
                        "hypothesis": hyp, "verdict": verdict, **s})
            print(f"  {name}: {s} -> {verdict}")
        log.append({"cell": f"{arch}/{shape}", "variant": "<<WINNER>>",
                    "hypothesis": best_name, **best})
        print(f"  WINNER: {best_name}: adj bound "
              f"{best.get('adj_bound_s')}s adj frac "
              f"{best.get('adj_frac')}")
    emit("perf_iterations", log,
         keys=["cell", "variant", "status", "compute_s",
               "mem_analytic_s", "collective_s", "useful_ratio",
               "adj_bound_s", "adj_frac", "verdict"])
    return log


if __name__ == "__main__":
    from repro.launch.presets import get_preset as _gp

    _gp("full").ensure_host_devices()
    run()
