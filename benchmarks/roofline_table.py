"""§Roofline: the 40-cell baseline table from the dry-run artifacts
(single-pod mesh), plus the TPU analytic model's prediction per cell
(§Model-accuracy, the Fig. 4/5 analogue for the TPU domain).
"""
from __future__ import annotations

from repro.configs import get_arch, get_shape
from repro.core.analytical.tpu_model import ShardPlan, TPUPlan, analyze

from benchmarks.common import emit, load_dryrun_artifacts


def _default_plan(cfg, shape, m):
    attn = "heads" if cfg.n_heads % 16 == 0 and cfg.family != "ssm" \
        else "seq"
    df = "IS" if shape.kind == "train" else "WS"
    sp = ShardPlan(df, attn, 16)
    return TPUPlan(sp=0, front=sp, tail=sp, microbatches=m,
                   remat="full", dp=16, pods=1)


def run(mesh: str = "single"):
    rows = []
    for art in load_dryrun_artifacts(mesh):
        if art["status"] == "SKIP":
            rows.append({"arch": art["arch"], "shape": art["shape"],
                         "status": "SKIP", "note": art["reason"][:48]})
            continue
        if art["status"] != "OK":
            rows.append({"arch": art["arch"], "shape": art["shape"],
                         "status": "FAIL", "note": art["error"][:48]})
            continue
        r = art["roofline"]
        cfg = get_arch(art["arch"])
        shape = get_shape(art["shape"])
        plan = _default_plan(cfg, shape, art.get("microbatches", 1))
        pred = analyze(cfg, shape, plan)
        rows.append({
            "arch": art["arch"], "shape": art["shape"], "status": "OK",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": r["roofline_fraction"],
            "pred_compute_s": pred.compute_s,
            "pred_dominant": pred.dominant,
            "note": "",
        })
    emit(f"roofline_table_{mesh}", rows,
         keys=["arch", "shape", "status", "compute_s", "memory_s",
               "collective_s", "dominant", "useful_ratio",
               "roofline_frac"])
    ok = [r for r in rows if r["status"] == "OK"]
    if ok:
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"[roofline/{mesh}] {len(ok)} OK cells; dominant terms: "
              f"{doms}")
    return {"cells": len(rows),
            "ok": len(ok),
            "fail": sum(r['status'] == 'FAIL' for r in rows),
            "pass": all(r["status"] != "FAIL" for r in rows)}


if __name__ == "__main__":
    run()
