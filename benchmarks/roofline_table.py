"""§Roofline: the 40-cell baseline table from the dry-run artifacts,
plus the TPU analytic model's prediction per cell (§Model-accuracy,
the Fig. 4/5 analogue for the TPU domain).

Runs against whichever preset's artifacts are present (``full``
preferred, else ``ci``); fails loudly with the generation command when
there are none.
"""
from __future__ import annotations

from repro.core.analytical.tpu_model import ShardPlan, TPUPlan, analyze
from repro.core.workload import lm_workload
from repro.launch.presets import get_preset

from benchmarks.common import emit, load_dryrun_artifacts, resolve_preset


def plan_from_artifact(cfg, shape, art) -> TPUPlan:
    """Rebuild the default level-2 plan for the mesh this artifact was
    lowered on (the seed hardcoded the production 16x16 geometry)."""
    axes = art.get("mesh_axes") or {"data": 16, "model": 16}
    model_axis = axes.get("model", 16)
    attn = "heads" if cfg.n_heads % model_axis == 0 \
        and cfg.family != "ssm" else "seq"
    df = "IS" if shape.kind == "train" else "WS"
    sp = ShardPlan(df, attn, model_axis)
    return TPUPlan(sp=0, front=sp, tail=sp,
                   microbatches=art.get("microbatches", 1),
                   remat=art.get("remat", "full"),
                   dp=axes.get("data", 16), pods=axes.get("pod", 1))


def run(mesh: str = "single", preset: str = None):
    preset = resolve_preset(preset)
    pset = get_preset(preset)
    rows = []
    for art in load_dryrun_artifacts(mesh, preset):
        if art["status"] == "SKIP":
            rows.append({"arch": art["arch"], "shape": art["shape"],
                         "status": "SKIP", "note": art["reason"][:48]})
            continue
        if art["status"] != "OK":
            rows.append({"arch": art["arch"], "shape": art["shape"],
                         "status": "FAIL", "note": art["error"][:48]})
            continue
        r = art["roofline"]
        cfg = pset.arch(art["arch"])
        shape = pset.shape(art["shape"])
        wl = lm_workload(cfg, shape)          # the cell's IR profile
        pred = analyze(wl, plan_from_artifact(cfg, shape, art))
        rows.append({
            "arch": art["arch"], "shape": art["shape"], "status": "OK",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": r["roofline_fraction"],
            "pred_compute_s": pred.compute_s,
            "pred_dominant": pred.dominant,
            "note": "",
        })
    emit(f"roofline_table_{mesh}", rows,
         keys=["arch", "shape", "status", "compute_s", "memory_s",
               "collective_s", "dominant", "useful_ratio",
               "roofline_frac"])
    ok = [r for r in rows if r["status"] == "OK"]
    if ok:
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"[roofline/{preset}/{mesh}] {len(ok)} OK cells; dominant "
              f"terms: {doms}")
    return {"preset": preset,
            "cells": len(rows),
            "ok": len(ok),
            "fail": sum(r['status'] == 'FAIL' for r in rows),
            "pass": len(ok) > 0
            and all(r["status"] != "FAIL" for r in rows)}


def run_all_meshes(preset: str = None):
    """Both mesh columns of the table, as one benchmark entry."""
    single = run("single", preset)
    multi = run("multi", preset)
    return {"preset": single["preset"],
            "cells": single["cells"] + multi["cells"],
            "ok": single["ok"] + multi["ok"],
            "fail": single["fail"] + multi["fail"],
            "pass": single["pass"] and multi["pass"]}


if __name__ == "__main__":
    run_all_meshes()
