"""Benchmark driver: one module per paper table/figure + the TPU-domain
roofline/model reports. ``python -m benchmarks.run [--quick]``.

``--list`` prints the available benchmark names; every run writes a
machine-readable ``<artifacts>/bench/results.json`` (per-benchmark
metrics + wall seconds) so the perf trajectory is tracked across PRs
(CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time


def build_benches(quick: bool = False) -> list:
    """The single source of truth: (name, module, entry, args, kwargs).

    Modules are imported lazily at execution time, so ``--list`` stays
    cheap and a name here is always both listable and runnable.
    """
    n_cases = 6 if quick else 12
    fig11_kw = {"n_particles": 12, "n_iters": 12} if quick else {}
    # quick: shrink the trace + paged-comparison window; full: the
    # mixed-context trace spans 128..4k-token contexts
    serve_kw = ({"n_requests": 8, "max_new": 6, "mixed_max_len": 256}
                if quick else {"mixed_max_len": 4096, "mixed_requests": 12})
    return [
        ("fig4", "fig4_pipeline_model_error", "run", (), {}),
        ("fig5", "fig5_generic_model_error", "run", (), {}),
        ("fig6", "fig6_ctc", "run", (), {}),
        ("fig8", "fig8_dsp_efficiency", "run", (n_cases,), {}),
        ("fig9", "fig9_resource_split", "run", (n_cases,), {}),
        ("fig10", "fig10_scalability", "run", (), {}),
        ("fig11", "fig11_dse_convergence", "run", (), fig11_kw),
        # live serving workload: open-loop trace through the ServeEngine,
        # measured tok/s + latency percentiles vs analytical predictions
        ("serve_throughput", "serve_throughput", "run", (), serve_kw),
        # dry-run consumers: need artifacts (repro.launch.dryrun);
        # they raise with the generation command when none exist
        ("roofline", "roofline_table", "run_all_meshes", (), {}),
        ("tpu_model", "tpu_model_error", "run", (), {}),
        # kernel-calibration consumer: needs artifacts/kernels/
        # calibration.json (repro.kernels.tune); raises with the
        # generation command when none exists
        ("kernel_model_error", "kernel_model_error", "run", (), {}),
        # static-analysis smoke: ci-preset passes over the live tree;
        # pass/finding counts tracked like every other metric
        ("analysis", "analysis_smoke", "run", (), {}),
    ]


def benchmark_names() -> list:
    return [b[0] for b in build_benches()]


def write_results(results: dict, quick: bool = False,
                  only: str = None) -> str:
    """Persist the per-benchmark metric dicts + timings as JSON.

    Records the run mode (quick/only + the full roster) so trajectory
    consumers never compare a 2-benchmark quick run against a full one.
    """
    from repro.artifacts import bench_dir

    os.makedirs(bench_dir(), exist_ok=True)
    path = os.path.join(bench_dir(), "results.json")
    results = {k: {**r, "pass": bool(r.get("pass"))}
               for k, r in results.items()}
    payload = {
        "generated_unix": time.time(),
        "quick": bool(quick),
        "only": sorted(only.split(",")) if only else None,
        "available": benchmark_names(),
        "ran": sorted(results),
        "benchmarks": results,
        "pass": all(r["pass"] for r in results.values()),
    }

    def _default(o):                    # numpy scalars -> plain numbers
        return o.item() if hasattr(o, "item") else str(o)

    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_default)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer DSE cases for fig8/9, smaller fig11 swarm")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--list", action="store_true",
                    help="print available benchmark names and exit")
    args = ap.parse_args()

    if args.list:
        for n in benchmark_names():
            print(n)
        return

    benches = build_benches(args.quick)
    if args.only:
        names = set(args.only.split(","))
        unknown = names - {b[0] for b in benches}
        if unknown:
            sys.exit(f"unknown benchmark(s): {sorted(unknown)}; "
                     f"available: {benchmark_names()}")
        benches = [b for b in benches if b[0] in names]

    results = {}
    t_all = time.time()
    for name, mod, entry, b_args, b_kwargs in benches:
        t0 = time.time()
        try:
            fn = getattr(importlib.import_module(f"benchmarks.{mod}"),
                         entry)
            results[name] = fn(*b_args, **b_kwargs)
            results[name]["seconds"] = round(time.time() - t0, 1)
        except Exception as e:                        # noqa: BLE001
            results[name] = {"pass": False,
                             "seconds": round(time.time() - t0, 1),
                             "error": f"{type(e).__name__}: {e}"}
            import traceback
            traceback.print_exc()

    path = write_results(results, quick=args.quick, only=args.only)
    print("\n==== SUMMARY ====")
    ok = True
    for name, r in results.items():
        status = "PASS" if r.get("pass") else "FAIL"
        ok &= bool(r.get("pass"))
        extra = {k: v for k, v in r.items()
                 if k not in ("pass",) and not isinstance(v, (list, dict))}
        print(f"{status:4s} {name:18s} {extra}")
    print(f"total {time.time() - t_all:.0f}s -> {path}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
