"""Benchmark driver: one module per paper table/figure + the TPU-domain
roofline/model reports. ``python -m benchmarks.run [--quick]``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer DSE cases for fig8/9, smaller fig11 swarm")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (
        fig4_pipeline_model_error,
        fig5_generic_model_error,
        fig6_ctc,
        fig8_dsp_efficiency,
        fig9_resource_split,
        fig10_scalability,
        fig11_dse_convergence,
        roofline_table,
        tpu_model_error,
    )

    n_cases = 6 if args.quick else 12
    fig11_kw = ({"n_particles": 12, "n_iters": 12} if args.quick else {})
    benches = [
        ("fig4", lambda: fig4_pipeline_model_error.run()),
        ("fig5", lambda: fig5_generic_model_error.run()),
        ("fig6", lambda: fig6_ctc.run()),
        ("fig8", lambda: fig8_dsp_efficiency.run(n_cases)),
        ("fig9", lambda: fig9_resource_split.run(n_cases)),
        ("fig10", lambda: fig10_scalability.run()),
        ("fig11", lambda: fig11_dse_convergence.run(**fig11_kw)),
        # dry-run consumers: need artifacts (repro.launch.dryrun);
        # they raise with the generation command when none exist
        ("roofline", lambda: roofline_table.run_all_meshes()),
        ("tpu_model", lambda: tpu_model_error.run()),
    ]
    if args.only:
        names = set(args.only.split(","))
        unknown = names - {n for n, _ in benches}
        if unknown:
            sys.exit(f"unknown benchmark(s): {sorted(unknown)}; "
                     f"available: {[n for n, _ in benches]}")
        benches = [(n, f) for n, f in benches if n in names]

    results = {}
    t_all = time.time()
    for name, fn in benches:
        t0 = time.time()
        try:
            results[name] = fn()
            results[name]["seconds"] = round(time.time() - t0, 1)
        except Exception as e:                        # noqa: BLE001
            results[name] = {"pass": False,
                             "error": f"{type(e).__name__}: {e}"}
            import traceback
            traceback.print_exc()

    print("\n==== SUMMARY ====")
    ok = True
    for name, r in results.items():
        status = "PASS" if r.get("pass") else "FAIL"
        ok &= bool(r.get("pass"))
        extra = {k: v for k, v in r.items()
                 if k not in ("pass",) and not isinstance(v, (list, dict))}
        print(f"{status:4s} {name:18s} {extra}")
    print(f"total {time.time() - t_all:.0f}s")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
