"""Fig. 5 reproduction: generic (paradigm 2) analytic model vs the event
simulator over 36 CONV cases — fmap (56,112,224) x channels
(64,128,256,512) x kernel (1,3,5) on VU9P.

Paper: 2.17% average error vs board measurements.

Each case is the registry's ``conv_case`` workload (CNN front-end).
"""
from __future__ import annotations

from repro.core.analytical.generic import generic_dse
from repro.core.hardware import VU9P
from repro.core.workload import get_workload
from repro.sim.simulator import simulate_generic

from benchmarks.common import emit


def run():
    rows = []
    for fm in (56, 112, 224):
        for ch in (64, 128, 256, 512):
            for k in (1, 3, 5):
                wl = get_workload("conv_case", fmap=fm, cin=ch, k=k)
                d = generic_dse(wl, VU9P)
                s = simulate_generic(d, VU9P)
                err = (d.gops() - s.gops) / s.gops * 100
                rows.append({"fmap": fm, "ch": ch, "k": k,
                             "analytic_gops": d.gops(),
                             "sim_gops": s.gops, "err_pct": err,
                             "dataflow": d.dataflows[0]})
    avg = sum(abs(r["err_pct"]) for r in rows) / len(rows)
    emit("fig5_generic_model_error", rows)
    print(f"[fig5] 36 cases avg |err| = {avg:.2f}%  (paper: 2.17%)")
    return {"avg_err_pct": avg, "paper_err_pct": 2.17,
            "pass": avg <= 4.0}


if __name__ == "__main__":
    run()
