"""Shared benchmark helpers: result rows + CSV/markdown emission."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def emit(name: str, rows: List[Dict], keys=None):
    """Print a compact table and save JSON under artifacts/bench/."""
    os.makedirs(os.path.join(ART_DIR, "bench"), exist_ok=True)
    path = os.path.join(ART_DIR, "bench", name + ".json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    if rows:
        keys = keys or list(rows[0].keys())
        print(f"\n== {name} ==")
        print(",".join(keys))
        for r in rows:
            print(",".join(_fmt(r.get(k)) for k in keys))
    return path


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def load_dryrun_artifacts(mesh: str = "single") -> List[Dict]:
    d = os.path.join(ART_DIR, "dryrun")
    out = []
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if name.endswith(f"__{mesh}.json"):
            with open(os.path.join(d, name)) as f:
                out.append(json.load(f))
    return out


class timed:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
