"""Shared benchmark helpers: artifact-tree routing, dry-run artifact
loading (loud on absence), result rows + CSV/markdown emission."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from repro.artifacts import artifact_root, bench_dir, dryrun_dir, list_cells

GENERATE_HINT = (
    "no dry-run artifacts found under {root}/dryrun/ — generate the "
    "CI-scale set first:\n"
    "    PYTHONPATH=src python -m repro.launch.dryrun --preset ci\n"
    "(minutes on a CPU-only host; use --preset full for the production "
    "16x16 / 2x16x16 meshes — hours. See README §Dry-run artifacts.)")


class DryRunArtifactsMissing(RuntimeError):
    """Raised instead of silently returning an empty artifact list —
    the seed behaviour let roofline/tpu_model 'pass' with empty tables
    and a zero exit code."""


def available_presets() -> List[str]:
    """Presets with at least one generated cell, preference-ordered
    (paper-scale `full` wins over `ci` when both exist)."""
    return [p for p in ("full", "ci") if list_cells(p)]


def resolve_preset(preset: Optional[str] = None) -> str:
    """Pick which preset's artifacts to consume, or fail loudly."""
    if preset is not None:
        if not list_cells(preset):
            raise DryRunArtifactsMissing(
                f"no dry-run artifacts for preset {preset!r} under "
                f"{dryrun_dir(preset)} — generate them with:\n"
                f"    PYTHONPATH=src python -m repro.launch.dryrun "
                f"--preset {preset}")
        return preset
    avail = available_presets()
    if not avail:
        raise DryRunArtifactsMissing(
            GENERATE_HINT.format(root=artifact_root()))
    return avail[0]


def load_dryrun_artifacts(mesh: str = "single",
                          preset: Optional[str] = None) -> List[Dict]:
    """All cell artifacts for one mesh of one preset (auto-detected
    when ``preset`` is None). Raises :class:`DryRunArtifactsMissing`
    rather than returning an empty list."""
    preset = resolve_preset(preset)
    d = dryrun_dir(preset)
    out = []
    for name in list_cells(preset):
        if name.endswith(f"__{mesh}.json"):
            with open(os.path.join(d, name)) as f:
                art = json.load(f)
            art.setdefault("preset", preset)
            out.append(art)
    if not out:
        raise DryRunArtifactsMissing(
            f"preset {preset!r} has artifacts under {d} but none for "
            f"mesh {mesh!r} — regenerate with:\n"
            f"    PYTHONPATH=src python -m repro.launch.dryrun "
            f"--preset {preset}")
    return out


def emit(name: str, rows: List[Dict], keys=None):
    """Print a compact table and save JSON under <artifacts>/bench/."""
    os.makedirs(bench_dir(), exist_ok=True)
    path = os.path.join(bench_dir(), name + ".json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    if rows:
        keys = keys or list(rows[0].keys())
        print(f"\n== {name} ==")
        print(",".join(keys))
        for r in rows:
            print(",".join(_fmt(r.get(k)) for k in keys))
    return path


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class timed:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
